"""Sequence-parallel decode: flash-decoding over sharded KV/latent caches.

Decode reads one query token against a long KV cache, so the cache —
not the heads — is the tensor worth sharding: its sequence dim lives on
the "model" mesh axis (tag ``sp_seq``) and GSPMD turns the softmax
reduction into the per-shard partial-attention + logsumexp-combine of
flash-decoding.  Cache writes (``sp_*_update``) are dynamic-slice
updates that only touch the owning shard.

All functions are pure and mesh-agnostic: outside a ``use_rules``
context they are the single-device reference (the oracle the multidevice
tests compare against).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .sharding import shard
from ..models.attention import expand_kv as _expand_kv

NEG_INF = -1e30


def sp_decode_attention(q, k_cache, v_cache, index, *,
                        sm_scale: float | None = None) -> jnp.ndarray:
    """One-token GQA attention over the cache prefix [0, index].

    q: (B, 1, Hq, Dh); k_cache/v_cache: (B, Smax, Hkv, Dh) —
    sequence-sharded under SP rules.  Returns (B, 1, Hq, Dh).
    """
    B, S, _, Dh = k_cache.shape
    Hq = q.shape[2]
    k_cache = shard(k_cache, "batch", "sp_seq", None, None)
    v_cache = shard(v_cache, "batch", "sp_seq", None, None)
    k = _expand_kv(k_cache, Hq).astype(jnp.float32)
    v = _expand_kv(v_cache, Hq).astype(jnp.float32)
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(Dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale, k)
    mask = jnp.arange(S)[None, None, None, :] <= index
    s = jnp.where(mask, s, NEG_INF)
    # explicit max-shifted softmax: under SP the max/sum lower to the
    # flash-decoding logsumexp combine across sequence shards
    m = jax.lax.stop_gradient(s.max(-1, keepdims=True))
    p = jnp.exp(s - m)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v) \
        / jnp.maximum(p.sum(-1)[..., None].swapaxes(1, 2), 1e-20)
    return out.astype(q.dtype)


def sp_decode_attention_latent(q_lat, q_rope, lat_cache, rope_cache, index,
                               *, nope_dim: int, rope_dim: int):
    """MLA absorbed decode: attention in the latent space.

    q_lat: (B, H, C) — q_nope already absorbed through W_uk;
    q_rope: (B, H, R); lat_cache: (B, Smax, C); rope_cache: (B, Smax, R).
    Returns o_lat (B, H, C) in fp32 (caller applies W_uv).
    """
    S = lat_cache.shape[1]
    lat = shard(lat_cache, "batch", "sp_seq", None).astype(jnp.float32)
    rope = shard(rope_cache, "batch", "sp_seq", None).astype(jnp.float32)
    scale = 1.0 / math.sqrt(nope_dim + rope_dim)
    s = (jnp.einsum("bhc,bsc->bhs", q_lat.astype(jnp.float32), lat)
         + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32), rope))
    s = s * scale
    mask = jnp.arange(S)[None, None, :] <= index
    s = jnp.where(mask, s, NEG_INF)
    m = jax.lax.stop_gradient(s.max(-1, keepdims=True))
    p = jnp.exp(s - m)
    return jnp.einsum("bhs,bsc->bhc", p, lat) \
        / jnp.maximum(p.sum(-1, keepdims=True), 1e-20)


def sp_cache_update(cache, new, index) -> jnp.ndarray:
    """Write one token's KV row: cache (B, Smax, Hkv, Dh), new
    (B, 1, Hkv, Dh) at sequence position ``index``.  Under SP rules the
    dynamic-slice update only touches the shard owning ``index``."""
    cache = shard(cache, "batch", "sp_seq", None, None)
    out = jax.lax.dynamic_update_slice_in_dim(
        cache, new.astype(cache.dtype), index, axis=1)
    return shard(out, "batch", "sp_seq", None, None)


def sp_latent_cache_update(cache, new, index) -> jnp.ndarray:
    """Latent-cache variant: cache (B, Smax, C), new (B, 1, C)."""
    cache = shard(cache, "batch", "sp_seq", None)
    out = jax.lax.dynamic_update_slice_in_dim(
        cache, new.astype(cache.dtype), index, axis=1)
    return shard(out, "batch", "sp_seq", None)
